"""Streaming golden-equivalence suite: temporal tile-reuse must never
change what the detector reports.

- static video => identical boxes to per-frame ``detect`` with >90% of
  tiles skipped after the first frame;
- threshold-0 mode => bit-identical boxes on arbitrary video (moving
  faces, pans), whatever mix of cached/incremental/full frames it takes;
- keyframe refresh bounds staleness when a positive threshold suppresses
  recomputation;
- the tile->window mapping is conservative (never misses a changed
  window), and overflow/fallback paths degrade to full refresh, not to
  wrong answers.
"""

import numpy as np
import pytest

from repro.core import Detector, EngineConfig, paper_shaped_cascade
from repro.core.cascade import WINDOW
from repro.core.pyramid import downscale_indices
from repro.stream import (StreamConfig, StreamEngine, VideoDetector,
                          changed_window_mask, dilate_tiles, make_video,
                          tile_change_scores)
from repro.stream.engine import StreamGeometry

CASC = paper_shaped_cascade(0, stage_sizes=[3, 4, 5, 6, 8])
KW = dict(step=2, scale_factor=1.3, min_neighbors=2)
HW = 96


@pytest.fixture(scope="module")
def det():
    return Detector(CASC, EngineConfig(mode="wave", **KW))


@pytest.fixture(scope="module")
def engine(det):
    # one shared engine so every test reuses the same jitted programs
    return StreamEngine(det, StreamConfig().max_changed_frac)


def _stream(det, engine, **cfg):
    return VideoDetector(det, StreamConfig(**cfg), engine=engine)


# ---------------------------------------------------------------- identity
def test_static_video_identical_with_skips(det, engine):
    rng = np.random.default_rng(5)
    from repro.core.training.data import render_scene
    frame = render_scene(rng, HW, HW, n_faces=1)[0]
    base = det.detect(frame)
    vd = _stream(det, engine, tile=16, threshold=0.0, keyframe_interval=0)
    for t in range(5):
        rects, st = vd.process(frame)
        assert np.array_equal(rects, base)
        if t == 0:
            assert st.mode == "full"
        else:
            assert st.mode == "cached"
            assert st.tile_skip_frac == 1.0
            assert st.tile_skip_frac > 0.9          # the ISSUE's bar
            assert st.windows_recomputed == 0


@pytest.mark.parametrize("kind", ["static_cctv", "moving_face",
                                  "camera_pan"])
def test_threshold0_bit_identical(det, engine, kind):
    video = make_video(kind, n_frames=4, h=HW, w=HW, seed=11)
    vd = _stream(det, engine, tile=12, threshold=0.0, keyframe_interval=0)
    modes = []
    for frame, _gt in video:
        rects, st = vd.process(frame)
        assert np.array_equal(rects, det.detect(frame)), \
            f"{kind} frame {st.frame_idx} ({st.mode}) diverged"
        modes.append(st.mode)
    if kind == "static_cctv":   # the small moving object stays incremental
        assert "incremental" in modes


def test_incremental_path_skips_windows(det, engine):
    video = make_video("static_cctv", n_frames=4, h=HW, w=HW, seed=2)
    vd = _stream(det, engine, tile=12, threshold=0.0, keyframe_interval=0)
    for i, (frame, _gt) in enumerate(video):
        rects, st = vd.process(frame)
        if i > 0:
            assert st.mode == "incremental"
            assert 0 < st.windows_recomputed < st.windows_total
            assert st.window_skip_frac > 0.5
        assert np.array_equal(rects, det.detect(frame))


# ---------------------------------------------------------------- keyframe
def test_keyframe_bounds_staleness(det, engine):
    video_a = make_video("static_cctv", n_frames=1, h=HW, w=HW, seed=3)
    video_b = make_video("static_cctv", n_frames=1, h=HW, w=HW, seed=4)
    frame_a, frame_b = video_a[0][0], video_b[0][0]
    base_a, base_b = det.detect(frame_a), det.detect(frame_b)
    # a huge threshold suppresses all incremental recomputation: only the
    # keyframe cadence refreshes the cache
    vd = _stream(det, engine, tile=16, threshold=1e12, keyframe_interval=4)
    for _ in range(3):
        rects, st = vd.process(frame_a)
    # scene cut: frames 3.. show scene B, but stay stale until the keyframe
    rects, st = vd.process(frame_b)
    assert st.mode == "cached"
    assert np.array_equal(rects, base_a)            # stale by design
    rects, st = vd.process(frame_b)                 # frame 4 == keyframe
    assert st.mode == "full"
    assert np.array_equal(rects, base_b)            # staleness bounded


def test_keyframe_disabled_never_refreshes(det, engine):
    frame = make_video("static_cctv", n_frames=1, h=HW, w=HW, seed=3)[0][0]
    vd = _stream(det, engine, tile=16, threshold=1e12, keyframe_interval=0)
    vd.process(frame)
    for _ in range(6):
        _rects, st = vd.process(frame)
        assert st.mode == "cached"


# ----------------------------------------------------------- tile mapping
def test_tile_change_scores_localized():
    prev = np.full((64, 64), 100.0, np.float32)
    cur = prev.copy()
    cur[40, 9] += 3.0
    scores, changed_any = tile_change_scores(prev, cur, tile=16)
    assert changed_any.sum() == 1 and changed_any[2, 0]
    assert scores[2, 0] > 0
    assert (scores[~changed_any] < 1e-6).all()


def test_tile_change_any_immune_to_absorption():
    """A tiny change must be flagged even next to a huge one (float SAT
    partial sums would absorb it; the exact any-reduction must not)."""
    prev = np.zeros((64, 64), np.float32)
    cur = prev.copy()
    cur[:16, :16] = 255.0                 # huge change, tile (0,0)
    cur[40, 40] = 1e-4                    # tiny change, tile (2,2)
    _scores, changed_any = tile_change_scores(prev, cur, tile=16)
    assert changed_any[0, 0] and changed_any[2, 2]
    assert changed_any.sum() == 2


def test_dilate_tiles():
    m = np.zeros((5, 5), bool)
    m[2, 2] = True
    d = dilate_tiles(m, 1)
    assert d.sum() == 5 and d[1, 2] and d[3, 2] and d[2, 1] and d[2, 3]
    assert dilate_tiles(m, 0) is m


def test_changed_window_mask_is_conservative(det):
    """Property: every window whose receptive field touches a changed pixel
    must be in the mask (brute force over the nearest-neighbour map)."""
    rng = np.random.default_rng(9)
    geo = StreamGeometry(det, 64, 64)
    tile = 16
    for _ in range(3):
        changed = rng.random((4, 4)) < 0.3
        if not changed.any():
            continue
        pix = np.repeat(np.repeat(changed, tile, 0), tile, 1)
        for lv, (ny, nx) in zip(geo.plan, geo.level_windows):
            mask = changed_window_mask(
                changed, tile, 64, 64, lv, geo.step,
                lv.height - WINDOW, lv.width - WINDOW).reshape(ny, nx)
            ys_map = downscale_indices(64, lv.height)
            xs_map = downscale_indices(64, lv.width)
            for iy in range(ny):
                for ix in range(nx):
                    y, x = iy * geo.step, ix * geo.step
                    rows = ys_map[y:y + WINDOW]
                    cols = xs_map[x:x + WINDOW]
                    touches = pix[np.ix_(rows, cols)].any()
                    if touches:
                        assert mask[iy, ix], (lv, iy, ix)


# --------------------------------------------------------- capacity ladder
def test_cap_for_zero_changed_picks_smallest_rung(engine):
    from repro.stream.engine import STREAM_CAP_BASE
    geo = engine.geometry(HW, HW)
    assert geo.n_slots > STREAM_CAP_BASE     # fixture sanity
    assert engine._cap_for(geo.n_slots, 1, 0) == STREAM_CAP_BASE


def test_cap_for_rung_boundaries(engine):
    from repro.stream.engine import STREAM_CAP_BASE
    geo = engine.geometry(HW, HW)
    total = geo.n_slots
    # exactly at a rung: no promotion to the next power of two
    assert engine._cap_for(total, 1, STREAM_CAP_BASE) == STREAM_CAP_BASE
    at2 = 2 * STREAM_CAP_BASE
    if at2 <= total:
        assert engine._cap_for(total, 1, STREAM_CAP_BASE + 1) == at2
        assert engine._cap_for(total, 1, at2) == at2
    # the rung never exceeds the subset's own slot count
    assert engine._cap_for(10, 1, 9) == 10
    assert engine._cap_for(10, 2, 25) == 20
    # degenerate empty subset still yields a positive capacity
    assert engine._cap_for(0, 1, 0) == 1


def test_incremental_over_budget_returns_overflow(det):
    """More changed windows than cap_budget: nothing dispatches, the
    caller gets the overflow flag and must fall back to a full refresh."""
    tight = StreamEngine(det, 0.01)          # budget = 1% of windows
    geo = tight.geometry(HW, HW)
    masks = [np.ones(ny * nx, bool) for (ny, nx) in geo.level_windows]
    before = tight.dispatches
    bitmaps, counts, overflow = tight.incremental(
        [np.zeros((HW, HW), np.float32)], [masks], HW, HW)
    assert overflow
    assert bitmaps == []
    assert counts.sum() == geo.n_slots
    assert tight.dispatches == before        # no program ran


# ------------------------------------------------------- forced tail kernel
def test_stream_forced_pallas_tail_identical(det):
    """The packed-window kernel on the incremental path must reproduce
    per-frame detect bit-for-bit (the crossover ladder may route any rung
    through it, so every rung must be safe)."""
    kd = Detector(CASC, EngineConfig(mode="wave", tail_backend="pallas",
                                     **KW))
    vd = VideoDetector(kd, StreamConfig(tile=12, threshold=0.0,
                                        keyframe_interval=0))
    video = make_video("static_cctv", n_frames=3, h=HW, w=HW, seed=2)
    n_incr = 0
    for frame, _gt in video:
        rects, st = vd.process(frame)
        assert np.array_equal(rects, det.detect(frame))
        n_incr += st.mode == "incremental"
    assert n_incr >= 1


# ------------------------------------------------------------- fallbacks
def test_overflow_falls_back_to_full(det):
    """A capacity too small for the changed set must degrade to a full
    refresh with identical results, never drop windows."""
    small = StreamEngine(det, 0.0001)   # budget ~1 window: always overflows
    video = make_video("static_cctv", n_frames=3, h=HW, w=HW, seed=2)
    vd = VideoDetector(det, StreamConfig(tile=12, threshold=0.0,
                                         keyframe_interval=0,
                                         full_refresh_frac=1.1),
                       engine=small)
    saw_fallback = False
    for i, (frame, _gt) in enumerate(video):
        rects, st = vd.process(frame)
        assert np.array_equal(rects, det.detect(frame))
        if i > 0 and st.mode == "full":
            saw_fallback = True
    assert saw_fallback


def test_frame_shape_change_raises(det, engine):
    vd = _stream(det, engine, tile=16)
    vd.process(np.zeros((HW, HW), np.float32))
    with pytest.raises(ValueError, match="shape changed"):
        vd.process(np.zeros((HW, HW + 2), np.float32))
    with pytest.raises(ValueError, match="grayscale"):
        VideoDetector(det).process(np.zeros((4, HW, HW), np.float32))


def test_sub_window_stream_is_empty(det, engine):
    vd = _stream(det, engine, tile=8)
    for _ in range(2):
        rects, _st = vd.process(np.zeros((10, 10), np.float32))
        assert rects.shape == (0, 4)


# ------------------------------------------------------- level subsetting
def test_incremental_plan_reports_active_levels(det, engine):
    video = make_video("static_cctv", n_frames=2, h=HW, w=HW, seed=2)
    vd = _stream(det, engine, tile=12, threshold=0.0, keyframe_interval=0)
    vd.process(video[0][0])
    _frame, plan = vd.plan_frame(video[1][0])
    assert plan.mode == "incremental"
    want = tuple(li for li, m in enumerate(plan.masks) if m.any())
    assert plan.active_levels == want
    assert len(plan.active_levels) >= 1


def test_fully_cached_levels_build_no_sat(det):
    """Padded bucket: a 48-row frame in a 64-row bucket has zero live
    windows at the coarsest pyramid level (its windows would sample padded
    pixels), so the level-subset engine must never build that level's SAT —
    and results must stay bit-identical to per-frame detect."""
    pad_det = Detector(CASC, EngineConfig(mode="wave", pad_multiple=64, **KW))
    engine = StreamEngine(pad_det, StreamConfig().max_changed_frac)
    video = make_video("static_cctv", n_frames=3, h=48, w=64, seed=6)
    vd = VideoDetector(pad_det, StreamConfig(tile=12, threshold=0.0,
                                             keyframe_interval=0,
                                             full_refresh_frac=1.1),
                       engine=engine)
    geo = engine.geometry(64, 64)
    dead = [li for li, (y_lim, _x) in enumerate(geo.limits(48, 64))
            if y_lim < 0]
    assert dead, "fixture must have at least one dead (fully-cached) level"
    n_incr = 0
    for i, (frame, _gt) in enumerate(video):
        before = engine.sat_level_builds
        rects, st = vd.process(frame)
        assert np.array_equal(rects, pad_det.detect(frame))
        if st.mode == "incremental":
            n_incr += 1
            built = engine.sat_level_builds - before
            # the dead level(s) never reach the head; the subset is smaller
            # than the full plan
            assert built == st.levels_active <= len(geo.plan) - len(dead)
            assert st.level_skip_frac > 0
    assert n_incr >= 1


def test_cached_frame_builds_no_sat(det, engine):
    """A bit-identical frame dispatches nothing: zero head invocations."""
    frame = make_video("static_cctv", n_frames=1, h=HW, w=HW, seed=3)[0][0]
    vd = _stream(det, engine, tile=16, threshold=0.0, keyframe_interval=0)
    vd.process(frame)
    before = (engine.sat_level_builds, engine.dispatches)
    _rects, st = vd.process(frame)
    assert st.mode == "cached"
    assert st.levels_active == 0 and st.level_skip_frac == 1.0
    assert (engine.sat_level_builds, engine.dispatches) == before


def test_empty_masks_incremental_is_noop(det, engine):
    """All-false masks (no changed windows anywhere) short-circuit: no
    program, empty survivor bitmaps."""
    geo = engine.geometry(HW, HW)
    masks = [np.zeros(ny * nx, bool) for (ny, nx) in geo.level_windows]
    frame = np.zeros((HW, HW), np.float32)
    before = engine.sat_level_builds
    bitmaps, counts, overflow = engine.incremental(
        [frame], [masks], HW, HW)
    assert not overflow
    assert engine.sat_level_builds == before
    assert counts.sum() == 0
    assert len(bitmaps) == 1 and not bitmaps[0].any()


def test_intermittent_stream_level_sat_frac(det, engine):
    """Mostly-idle stream: averaged over frames, fewer than half the
    pyramid levels' SATs are built, and output stays bit-identical."""
    video = make_video("intermittent_cctv", n_frames=8, h=HW, w=HW, seed=4)
    vd = _stream(det, engine, tile=12, threshold=0.0, keyframe_interval=0)
    fracs = []
    for i, (frame, _gt) in enumerate(video):
        rects, st = vd.process(frame)
        assert np.array_equal(rects, det.detect(frame))
        if i > 0:
            fracs.append(st.levels_active / max(st.levels_total, 1))
            assert st.mode in ("cached", "incremental")
    assert np.mean(fracs) < 0.5, fracs


# ------------------------------------------------------------- batch path
def test_batched_incremental_matches_single(det, engine):
    """Concurrent streams' changed windows share one packed compaction;
    per-frame results must equal the single-stream path."""
    videos = [make_video("static_cctv", n_frames=3, h=HW, w=HW, seed=s)
              for s in (0, 1)]
    vds = [_stream(det, engine, tile=12, threshold=0.0, keyframe_interval=0)
           for _ in videos]
    # frame 0: full per stream
    for vd, vid in zip(vds, videos):
        vd.process(vid[0][0])
    for t in range(1, 3):
        frames, plans = [], []
        for vd, vid in zip(vds, videos):
            frame, plan = vd.plan_frame(vid[t][0])
            assert plan.mode == "incremental"
            frames.append(frame)
            plans.append(plan)
        geo = vds[0]._geo
        bitmaps, _rec, overflow = engine.incremental(
            frames, [p.masks for p in plans], geo.hp, geo.wp)
        assert not overflow
        for vd, vid, plan, bm in zip(vds, videos, plans, bitmaps):
            rects, _st = vd.commit_incremental(vid[t][0], plan, bm)
            assert np.array_equal(rects, det.detect(vid[t][0]))
