"""Training substrate: loss descent, grad-accumulation equivalence,
optimizer invariants, schedules, gradient compression."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.train import init_train_state, make_train_step
from repro.train.losses import cross_entropy_loss
from repro.optim.adamw import (adamw_init, adamw_update,
                               cosine_schedule)
from repro.distributed.compression import (compress_leaf, decompress_leaf,
                                           make_compressor)

RNG = np.random.default_rng(0)


def test_loss_decreases_memorizing_batch():
    cfg = get_smoke_config("stablelm-1.6b")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, peak_lr=1e-3, warmup=3,
                                   total_steps=60))
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (4, 33)))}
    first = None
    for i in range(25):
        state, m = step(state, batch)
        first = first or float(m["loss"])
    assert float(m["loss"]) < first * 0.5


def test_grad_accumulation_matches_full_batch():
    cfg = get_smoke_config("olmo-1b")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (4, 17)))}
    s_full = jax.jit(make_train_step(model, peak_lr=1e-3, microbatch=0))
    s_acc = jax.jit(make_train_step(model, peak_lr=1e-3, microbatch=2))
    st1, m1 = s_full(state, batch)
    st2, m2 = s_acc(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_cross_entropy_matches_naive():
    logits = jnp.asarray(RNG.standard_normal((2, 5, 11)), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, 11, (2, 5)))
    loss, m = cross_entropy_loss(logits, labels, z_loss=0.0)
    naive = -jax.nn.log_softmax(logits)[
        jnp.arange(2)[:, None], jnp.arange(5)[None], labels].mean()
    np.testing.assert_allclose(float(loss), float(naive), rtol=1e-6)
    assert 0.0 <= float(m["accuracy"]) <= 1.0


def test_adamw_step_moves_toward_gradient():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.ones((4, 4))}
    st0 = adamw_init(params)
    p1, st1, m = adamw_update(params, grads, st0, lr=0.1, weight_decay=0.0)
    assert float(p1["w"][0, 0]) < 1.0
    assert int(st1.step) == 1
    assert float(m["grad_norm"]) == pytest.approx(4.0)


def test_adamw_chunked_update_matches_direct(monkeypatch):
    """Stacked-leaf streamed update == plain elementwise update."""
    import repro.optim.adamw as A
    big = jnp.asarray(RNG.standard_normal((16, 32, 24)), jnp.float32)
    g = jnp.asarray(RNG.standard_normal(big.shape), jnp.float32) * 0.01
    st0 = adamw_init({"w": big})
    monkeypatch.setattr(A, "CHUNK_MIN_SIZE", 1)      # force streamed path
    p_chunk, st1, _ = A.adamw_update({"w": big}, {"w": g}, st0, lr=0.01)
    monkeypatch.setattr(A, "CHUNK_MIN_SIZE", 1 << 60)   # force direct path
    p_dir, _, _ = A.adamw_update({"w": big}, {"w": g}, st0, lr=0.01)
    np.testing.assert_allclose(np.asarray(p_chunk["w"]),
                               np.asarray(p_dir["w"]),
                               rtol=1e-6, atol=1e-7)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), 1.0, 10, 100))
           for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(1.0)
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


@settings(max_examples=25, deadline=None)
@given(scale=st.floats(1e-6, 1e3),
       shape=st.sampled_from([(8,), (4, 5), (2, 3, 4)]))
def test_int8_compression_roundtrip_error_bound(scale, shape):
    g = jnp.asarray(RNG.standard_normal(shape), jnp.float32) * scale
    q, s = compress_leaf(g)
    back = decompress_leaf(q, s)
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-9


def test_error_feedback_preserves_mean_gradient():
    """With error feedback, the accumulated quantized sum tracks the true
    gradient sum (compression bias vanishes)."""
    compress, get_ef = make_compressor()
    true_sum = np.zeros((8, 8), np.float32)
    quant_sum = np.zeros((8, 8), np.float32)
    for i in range(50):
        g = {"w": jnp.asarray(RNG.standard_normal((8, 8)), jnp.float32)}
        true_sum += np.asarray(g["w"])
        quant_sum += np.asarray(compress(g)["w"])
    resid = np.abs(true_sum - quant_sum).max()
    ef = np.abs(np.asarray(get_ef()["w"])).max()
    assert resid <= ef + 1e-4      # all bias lives in the feedback buffer


def test_compressed_training_still_converges():
    cfg = get_smoke_config("olmo-1b")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    compress, _ = make_compressor()
    step = jax.jit(make_train_step(model, peak_lr=1e-3, warmup=3,
                                   total_steps=60, compress_grads=compress))
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (4, 33)))}
    first = None
    for i in range(25):
        state, m = step(state, batch)
        first = first or float(m["loss"])
    assert float(m["loss"]) < first * 0.6
