"""Launch-layer units: roofline HLO parsing, microbatch policy, cell
matrix, divisibility enforcement (no mesh/device-state needed)."""

import pytest

from repro.configs import SHAPES, list_archs, get_config
from repro.launch.cells import cell_applicable, CELL_SKIPS, \
    default_microbatch
from repro.launch.roofline import (collective_bytes_from_text,
                                   analytic_cost, model_flops, _shape_bytes)

HLO = """\
ENTRY %main.1 (p0: f32[16,16]) -> f32[16,16] {
  %ag = bf16[64,128]{1,0} all-gather(%x), channel_id=1
  %ar = f32[32]{0} all-reduce(%convert_fusion.1), channel_id=2
  %w = (s32[], f32[4]) while(%tuple), condition=%cond.1, body=%body.1
}
body.1 (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %rs = bf16[8,8]{1,0} reduce-scatter(%y), channel_id=3
}
cond.1 (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
"""


def test_collective_parser_kinds_factors_and_trips():
    out = collective_bytes_from_text(HLO)
    ag = 64 * 128 * 2                 # bf16, factor 1
    ar = 32 * 4 * 2                   # f32, factor 2 (ring)
    rs = 8 * 8 * 2 * 10               # bf16 × 10 loop trips
    assert out["per_kind"]["all-gather"] == ag
    assert out["per_kind"]["all-reduce"] == ar
    assert out["per_kind"]["reduce-scatter"] == rs
    assert out["total_bytes"] == ag + ar + rs
    # the f32 all-reduce consumes an inserted convert → bf16-normalized
    assert out["total_bytes_norm"] == ag + ar / 2 + rs
    assert out["n_while"] == 1


def test_shape_bytes_tuple_and_layout():
    assert _shape_bytes("(f32[2,3], bf16[4]) tuple") == 2 * 3 * 4 + 4 * 2
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4


def test_cell_matrix_is_40_with_8_documented_skips():
    total = len(list_archs()) * len(SHAPES)
    live = sum(cell_applicable(a, s) for a in list_archs() for s in SHAPES)
    assert total == 40
    assert live == 32
    assert len(CELL_SKIPS) == 8
    assert cell_applicable("mamba2-780m", "long_500k")
    assert cell_applicable("recurrentgemma-2b", "long_500k")
    assert not cell_applicable("llama3-405b", "long_500k")


@pytest.mark.parametrize("arch,chips", [("olmo-1b", 256),
                                        ("qwen2-72b", 256),
                                        ("llama3-405b", 256),
                                        ("llama3-405b", 512)])
def test_default_microbatch_divides_batch(arch, chips):
    cfg = get_config(arch)
    spec = SHAPES["train_4k"]
    mb = default_microbatch(cfg, spec, chips)
    if mb:
        assert spec.global_batch % mb == 0
        dp = chips // 16
        assert mb % dp == 0              # ≥ 1 sequence per data shard
    assert default_microbatch(cfg, SHAPES["decode_32k"], chips) == 0


def test_analytic_cost_scales_with_work():
    cfg = get_config("olmo-1b")
    tr = analytic_cost(cfg, SHAPES["train_4k"])
    pf = analytic_cost(cfg, SHAPES["prefill_32k"])
    dc = analytic_cost(cfg, SHAPES["decode_32k"])
    assert tr["flops"] > pf["flops"] > dc["flops"]
    # train ≈ 4×fwd on the same token count
    assert tr["flops"] / (tr["flops"] / 4) == pytest.approx(4)
    # 6ND within the analytic fwd (attention adds on top)
    mf = model_flops(cfg, SHAPES["train_4k"].tokens)
    assert 0.3 < mf / tr["flops"] < 1.0
    # decode is dominated by resident weights + cache reads
    assert dc["hbm_bytes"] > cfg.n_params() * 2


def test_enforce_divisibility_drops_uneven_axes():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import enforce_divisibility
    jax.make_mesh((1,), ("data",))          # single-device: every axis=1

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    fm = FakeMesh()
    assert enforce_divisibility(P("data", "model"), (32, 48), fm) \
        == P("data", "model")
    assert enforce_divisibility(P("data", None), (17, 48), fm) \
        == P(None, None)
    assert enforce_divisibility(P(("data", "model")), (256,), fm) \
        == P(("data", "model"))
    assert enforce_divisibility(P(("data", "model")), (136,), fm) \
        == P(None)
