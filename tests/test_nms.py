"""Regression tests for detection grouping: golden clusters, min_neighbors
edge cases at 0/1, transitive chaining, and the batched variant's exact
equivalence to per-image grouping."""

import numpy as np
import pytest

from repro.core import group_rectangles, group_rectangles_batch

# Golden fixture: two real clusters + one outlier.
CLUSTER_A = np.asarray([
    [10, 10, 20, 20],
    [11, 10, 20, 20],
    [10, 12, 20, 20],
    [12, 11, 20, 20],
])
CLUSTER_B = np.asarray([
    [50, 50, 24, 24],
    [51, 52, 24, 24],
    [49, 50, 24, 24],
])
OUTLIER = np.asarray([[100, 100, 10, 10]])
RECTS = np.concatenate([CLUSTER_A, CLUSTER_B, OUTLIER])


def test_golden_clusters_min_neighbors_2():
    """mn=2 keeps clusters with > 2 members: A (4) and B (3), not the
    singleton outlier."""
    got = group_rectangles(RECTS, min_neighbors=2)
    want = np.rint(np.stack([CLUSTER_A.mean(axis=0).astype(np.float64),
                             CLUSTER_B.mean(axis=0).astype(np.float64)])
                   ).astype(np.int32)
    assert np.array_equal(got, want)


def test_min_neighbors_3_drops_exact_size_cluster():
    """OpenCV parity: groupRectangles keeps a cluster iff its size is
    *strictly greater* than groupThreshold — a cluster of exactly
    ``min_neighbors`` members (B, 3 rects at mn=3) must be dropped."""
    got = group_rectangles(RECTS, min_neighbors=3)
    want = np.rint(CLUSTER_A.mean(axis=0)).astype(np.int32)[None]
    assert np.array_equal(got, want)


def test_min_neighbors_4_drops_exact_size_cluster():
    """A cluster of exactly min_neighbors members (A, 4 rects at mn=4) is
    dropped too — nothing survives."""
    got = group_rectangles(RECTS, min_neighbors=4)
    assert got.shape == (0, 4)


def test_min_neighbors_0_keeps_everything():
    """mn=0 keeps every cluster including singletons (size >= 1)."""
    got = group_rectangles(RECTS, min_neighbors=0)
    assert len(got) == 3                     # A, B, and the outlier cluster
    assert np.rint(OUTLIER[0]).astype(np.int32).tolist() in got.tolist()


def test_min_neighbors_1_drops_singletons():
    """mn=1 requires >= 2 members: the singleton outlier is dropped."""
    got = group_rectangles(RECTS, min_neighbors=1)
    assert len(got) == 2
    assert np.rint(OUTLIER[0]).astype(np.int32).tolist() not in got.tolist()


def test_empty_input():
    got = group_rectangles(np.zeros((0, 4)), min_neighbors=3)
    assert got.shape == (0, 4) and got.dtype == np.int32


def test_transitive_chaining_forms_one_cluster():
    """a~b and b~c but a!~c still union into a single cluster."""
    chain = np.asarray([[0, 0, 20, 20], [4, 0, 20, 20], [8, 0, 20, 20]])
    got = group_rectangles(chain, min_neighbors=2)
    assert len(got) == 1
    assert np.array_equal(got[0], np.rint(chain.mean(axis=0)).astype(np.int32))
    # ...but the 3-member chain does not survive mn=3 (needs > 3 members)
    assert group_rectangles(chain, min_neighbors=3).shape == (0, 4)


# ------------------------------------------------------------------ batched
def test_batched_matches_per_image_golden():
    rects = np.concatenate([RECTS, RECTS + 3])
    batch_idx = np.concatenate([np.zeros(len(RECTS), int),
                                np.ones(len(RECTS), int)])
    got = group_rectangles_batch(rects, batch_idx, min_neighbors=3)
    assert len(got) == 2
    for b in range(2):
        want = group_rectangles(rects[batch_idx == b], min_neighbors=3)
        assert np.array_equal(got[b], want)


@pytest.mark.parametrize("mn", [0, 1, 2, 3])
def test_batched_matches_per_image_random(mn):
    rng = np.random.default_rng(42)
    n, n_batches = 60, 4
    rects = np.stack([rng.integers(0, 80, n), rng.integers(0, 80, n),
                      rng.integers(10, 30, n), rng.integers(10, 30, n)],
                     axis=1)
    batch_idx = rng.integers(0, n_batches, n)
    got = group_rectangles_batch(rects, batch_idx, n_batches=n_batches,
                                 min_neighbors=mn)
    assert len(got) == n_batches
    for b in range(n_batches):
        want = group_rectangles(rects[batch_idx == b], min_neighbors=mn)
        assert np.array_equal(got[b], want)


def test_batched_never_merges_across_images():
    """Identical rects on different images must stay separate clusters."""
    rects = np.concatenate([CLUSTER_A, CLUSTER_A])
    batch_idx = np.concatenate([np.zeros(4, int), np.ones(4, int)])
    got = group_rectangles_batch(rects, batch_idx, min_neighbors=3)
    for b in range(2):
        assert len(got[b]) == 1
        assert np.array_equal(got[b][0],
                              np.rint(CLUSTER_A.mean(axis=0)).astype(np.int32))


def test_batched_empty():
    got = group_rectangles_batch(np.zeros((0, 4)), np.zeros(0, int),
                                 n_batches=3)
    assert len(got) == 3
    assert all(g.shape == (0, 4) for g in got)
