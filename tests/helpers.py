"""Shared test fixtures/builders (importable as ``from helpers import ...``
since pytest puts each rootless test directory on sys.path)."""

import numpy as np

from repro.core import make_cascade


def all_pass_cascade(n_stages: int = 4):
    """Every window passes every stage — maximal survivor pressure, used to
    force capacity-overflow paths."""
    n = n_stages
    rect_xywh = np.tile(np.asarray([[0, 0, 8, 8], [8, 0, 8, 8], [0, 0, 0, 0]],
                                   np.int32), (n, 1, 1))
    rect_w = np.tile(np.asarray([[1.0, -1.0, 0.0]], np.float32), (n, 1))
    return make_cascade(rect_xywh, rect_w,
                        np.zeros(n, np.float32),
                        np.full(n, 1.0, np.float32),
                        np.full(n, 1.0, np.float32),
                        np.arange(n + 1, dtype=np.int32),
                        np.full(n, -1e9, np.float32))
