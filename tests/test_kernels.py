"""Pallas kernels vs pure-jnp oracle: shape/dtype sweeps (hypothesis) in
interpret mode (CPU container; kernels target TPU BlockSpec tiling)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.core.integral import integral_images
from repro.core import load_cascade
from repro.configs.viola_jones import DEFAULT_PRETRAINED

CASC, _ = load_cascade(DEFAULT_PRETRAINED)


@settings(max_examples=8, deadline=None)
@given(h=st.integers(25, 140), w=st.integers(25, 180),
       scale=st.sampled_from([1.0, 255.0]))
def test_integral_image_kernel_matches_ref(h, w, scale):
    rng = np.random.default_rng(h * 1000 + w)
    img = jnp.asarray(rng.random((h, w), np.float32) * scale)
    got = ops.integral_image(img, interpret=True, use_kernel=True)
    want = ops.integral_image(img, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-2 * scale)


@settings(max_examples=6, deadline=None)
@given(h=st.integers(30, 100), w=st.integers(30, 120))
def test_window_inv_sigma_kernel_matches_ref(h, w):
    rng = np.random.default_rng(h * 77 + w)
    img = jnp.asarray(rng.integers(0, 255, (h, w)).astype(np.float32))
    _, ii_pair = integral_images(img)
    ny, nx = h - 24 + 1, w - 24 + 1
    got = ops.window_inv_sigma_grid(ii_pair, ny, nx, use_kernel=True)
    want = ops.window_inv_sigma_grid(ii_pair, ny, nx, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("stage", [0, 1])
@pytest.mark.parametrize("hw", [(40, 56), (64, 96)])
def test_haar_stage_kernel_matches_ref(stage, hw):
    if stage >= CASC.n_stages:
        pytest.skip("pretrained cascade has fewer stages")
    h, w = hw
    rng = np.random.default_rng(42)
    img = jnp.asarray(rng.integers(0, 255, (h, w)).astype(np.float32))
    ii, ii_pair = integral_images(img)
    ny, nx = h - 24 + 1, w - 24 + 1
    inv = ops.window_inv_sigma_grid(ii_pair, ny, nx, use_kernel=False)
    got = ops.dense_stage_sums(CASC, CASC, stage, ii, inv, interpret=True)
    want = ops.dense_stage_sums_ref(CASC, CASC, stage, ii, inv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_integral_image_property_last_cell_is_total():
    rng = np.random.default_rng(0)
    img = rng.integers(0, 255, (48, 64)).astype(np.float32)
    ii = np.asarray(ops.integral_image(jnp.asarray(img), use_kernel=True,
                                       interpret=True))
    assert abs(ii[-1, -1] - img.sum()) < 1e-2 * img.size
    assert (ii[0] == 0).all() and (ii[:, 0] == 0).all()


# ------------------------------------------------------------------ batched
def _batch_inputs(b, h, w, seed=0):
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.integers(0, 255, (b, h, w)).astype(np.float32))
    ii, pair = jax.vmap(integral_images)(imgs)
    return imgs, ii, pair


@pytest.mark.parametrize("stage", range(CASC.n_stages))
def test_dense_stage_sums_all_stages_match_ref(stage):
    """Kernel-vs-oracle across *every* cascade stage, on a grid that is not
    tile-aligned in either dimension (ny=17, nx=33 vs the (8, 128) tile)."""
    h, w = 40, 56
    rng = np.random.default_rng(7 * (stage + 1))
    img = jnp.asarray(rng.integers(0, 255, (h, w)).astype(np.float32))
    ii, ii_pair = integral_images(img)
    ny, nx = h - 24 + 1, w - 24 + 1
    inv = ops.window_inv_sigma_grid(ii_pair, ny, nx, use_kernel=False)
    got = ops.dense_stage_sums(CASC, CASC, stage, ii, inv, interpret=True)
    want = ops.dense_stage_sums_ref(CASC, CASC, stage, ii, inv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_integral_image_batch_matches_ref():
    imgs, _, _ = _batch_inputs(3, 37, 61)     # non-tile-aligned H and W
    got = ops.integral_image_batch(imgs, interpret=True, use_kernel=True)
    want = ops.integral_image_batch(imgs, use_kernel=False)
    assert got.shape == (3, 38, 62)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2.0)
    # per-slice equal to the single-image wrapper (same contract)
    for i in range(3):
        one = ops.integral_image(imgs[i], interpret=True, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(one))


def test_window_inv_sigma_batch_matches_ref():
    _, _, pair = _batch_inputs(2, 45, 70, seed=3)
    ny, nx = 45 - 24 + 1, 70 - 24 + 1
    got = ops.window_inv_sigma_grid_batch(pair, ny, nx, use_kernel=True,
                                          interpret=True)
    want = ops.window_inv_sigma_grid_batch(pair, ny, nx, use_kernel=False)
    assert got.shape == (2, ny, nx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)
    for i in range(2):
        one = ops.window_inv_sigma_grid(pair[i], ny, nx, use_kernel=True,
                                        interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(one))


@pytest.mark.parametrize("stage", range(CASC.n_stages))
def test_dense_stage_sums_batch_all_stages_match_ref(stage):
    _, ii, pair = _batch_inputs(2, 40, 56, seed=stage)
    ny, nx = 40 - 24 + 1, 56 - 24 + 1
    inv = ops.window_inv_sigma_grid_batch(pair, ny, nx, use_kernel=False)
    got = ops.dense_stage_sums_batch(CASC, CASC, stage, ii, inv,
                                     interpret=True)
    want = ops.dense_stage_sums_batch_ref(CASC, CASC, stage, ii, inv)
    assert got.shape == (2, ny, nx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)
    # each slice bit-equal to the single-image kernel (batch = vmap of it)
    for i in range(2):
        one = ops.dense_stage_sums(CASC, CASC, stage, ii[i], inv[i],
                                   interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(one))


# ---------------------------------------------------------------- oracles
# Direct kernel-vs-oracle races (repro.analysis KERNEL_REF_TEST contract:
# every public kernel must be checked against its *_ref twin by name, not
# only through the use_kernel=False convenience path).

def test_integral_image_vs_oracle_twin():
    rng = np.random.default_rng(7)
    img = jnp.asarray(rng.integers(0, 255, (48, 72)).astype(np.float32))
    got = ops.integral_image(img, interpret=True, use_kernel=True)
    want = jnp.pad(ref.integral_image_ref(img), ((1, 0), (1, 0)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-2)


def test_integral_image_batch_vs_oracle_twin():
    rng = np.random.default_rng(11)
    imgs = jnp.asarray(rng.integers(0, 255, (3, 40, 56)).astype(np.float32))
    got = ops.integral_image_batch(imgs, interpret=True, use_kernel=True)
    want = jnp.pad(ref.integral_image_batch_ref(imgs),
                   ((0, 0), (1, 0), (1, 0)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-2)


def test_window_inv_sigma_grid_vs_oracle_twin():
    rng = np.random.default_rng(13)
    img = jnp.asarray(rng.integers(0, 255, (52, 68)).astype(np.float32))
    _, ii_pair = integral_images(img)
    ny, nx = 52 - 24 + 1, 68 - 24 + 1
    got = ops.window_inv_sigma_grid(ii_pair, ny, nx, use_kernel=True)
    want = ref.window_inv_sigma_grid_ref(ii_pair, ny, nx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


def test_window_inv_sigma_grid_batch_vs_oracle_twin():
    rng = np.random.default_rng(17)
    imgs = rng.integers(0, 255, (2, 44, 60)).astype(np.float32)
    pairs = jnp.stack([integral_images(jnp.asarray(im))[1] for im in imgs])
    ny, nx = 44 - 24 + 1, 60 - 24 + 1
    got = ops.window_inv_sigma_grid_batch(pairs, ny, nx, use_kernel=True)
    want = ref.window_inv_sigma_grid_batch_ref(pairs, ny, nx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


# ------------------------------------------------------------------ fused
N_RUN = min(3, CASC.n_stages)     # the megakernel's dense stage run


def test_fused_head_vs_oracle_twin():
    """ops.fused_head vs ref.fused_head_ref on a non-tile-aligned grid
    (ny=17, nx=33), all three outputs."""
    h, w = 40, 56
    rng = np.random.default_rng(23)
    img = jnp.asarray(rng.integers(0, 255, (h, w)).astype(np.float32))
    ii, inv, sums = ops.fused_head(CASC, CASC, 0, N_RUN, img,
                                   interpret=True)
    ii_r, inv_r, sums_r = ops.fused_head_ref(CASC, CASC, 0, N_RUN, img)
    assert sums.shape == (N_RUN, h - 24 + 1, w - 24 + 1)
    np.testing.assert_allclose(np.asarray(ii), np.asarray(ii_r),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(np.asarray(inv), np.asarray(inv_r),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_r),
                               rtol=1e-4, atol=1e-3)
    # the module-level oracle twin is the same function ops re-exports
    ii_m, inv_m, sums_m = ref.fused_head_ref(
        CASC.rect_xywh[:CASC.stage_offsets[N_RUN]],
        CASC.rect_w[:CASC.stage_offsets[N_RUN]],
        CASC.wc_threshold[:CASC.stage_offsets[N_RUN]],
        CASC.left_val[:CASC.stage_offsets[N_RUN]],
        CASC.right_val[:CASC.stage_offsets[N_RUN]],
        tuple(int(b) for b in CASC.stage_offsets[:N_RUN + 1]), img)
    np.testing.assert_array_equal(np.asarray(sums_r), np.asarray(sums_m))


def test_fused_head_batch_vs_oracle_twin():
    rng = np.random.default_rng(29)
    imgs = jnp.asarray(rng.integers(0, 255, (3, 40, 56)).astype(np.float32))
    ii, inv, sums = ops.fused_head_batch(CASC, CASC, 0, N_RUN, imgs,
                                         interpret=True)
    ii_r, inv_r, sums_r = ops.fused_head_batch_ref(CASC, CASC, 0, N_RUN,
                                                   imgs)
    assert sums.shape == (3, N_RUN, 40 - 24 + 1, 56 - 24 + 1)
    np.testing.assert_allclose(np.asarray(ii), np.asarray(ii_r),
                               rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(np.asarray(inv), np.asarray(inv_r),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_r),
                               rtol=1e-4, atol=1e-3)
    assert "fused_head_batch_ref" in dir(ref)
    # each slice bit-equal to the single-image kernel (batch = vmap of it)
    for i in range(3):
        one = ops.fused_head(CASC, CASC, 0, N_RUN, imgs[i], interpret=True)
        for got_b, want_b in zip((ii[i], inv[i], sums[i]), one):
            np.testing.assert_array_equal(np.asarray(got_b),
                                          np.asarray(want_b))


@pytest.mark.parametrize("hw", [(40, 56), (25, 25), (31, 140)])
def test_fused_head_bit_identical_to_split_path(hw):
    """The engine's bit-exactness contract: under jit, the fused megakernel
    reproduces the split three-dispatch path (jnp SAT + jnp 1/sigma + one
    haar_stage dispatch per stage) to the last ulp, on tile-aligned and
    non-tile-aligned grids alike."""
    from repro.core.integral import window_inv_sigma

    h, w = hw
    rng = np.random.default_rng(h * 31 + w)
    img = jnp.asarray(rng.integers(0, 255, (h, w)).astype(np.float32))
    ny, nx = h - 24 + 1, w - 24 + 1

    def split(c, im):
        ii, pair = integral_images(im)
        inv = window_inv_sigma(pair, jnp.arange(ny)[:, None],
                               jnp.arange(nx)[None, :], 24)
        sums = jnp.stack([ops.dense_stage_sums(c, CASC, s, ii, inv,
                                               interpret=True)
                          for s in range(N_RUN)])
        return ii, inv, sums

    def fused(c, im):
        return ops.fused_head(c, CASC, 0, N_RUN, im, interpret=True)

    want = jax.jit(split)(CASC, img)
    got = jax.jit(fused)(CASC, img)
    for g, wnt in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wnt))


@pytest.mark.parametrize("tile", [(16, 128), (8, 256)])
def test_fused_head_tile_shape_does_not_change_bits(tile):
    """Autotuned block shapes are bit-exact-safe by construction: every
    per-window operation is elementwise over the tile, so racing candidate
    shapes can never change what the cascade computes."""
    rng = np.random.default_rng(37)
    img = jnp.asarray(rng.integers(0, 255, (40, 56)).astype(np.float32))
    base = ops.fused_head(CASC, CASC, 0, N_RUN, img, interpret=True)
    other = ops.fused_head(CASC, CASC, 0, N_RUN, img, tile=tile,
                           interpret=True)
    for g, wnt in zip(other, base):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(wnt))
