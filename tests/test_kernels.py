"""Pallas kernels vs pure-jnp oracle: shape/dtype sweeps (hypothesis) in
interpret mode (CPU container; kernels target TPU BlockSpec tiling)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.core.integral import integral_images
from repro.core import load_cascade
from repro.configs.viola_jones import DEFAULT_PRETRAINED

CASC, _ = load_cascade(DEFAULT_PRETRAINED)


@settings(max_examples=8, deadline=None)
@given(h=st.integers(25, 140), w=st.integers(25, 180),
       scale=st.sampled_from([1.0, 255.0]))
def test_integral_image_kernel_matches_ref(h, w, scale):
    rng = np.random.default_rng(h * 1000 + w)
    img = jnp.asarray(rng.random((h, w), np.float32) * scale)
    got = ops.integral_image(img, interpret=True, use_kernel=True)
    want = ops.integral_image(img, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-2 * scale)


@settings(max_examples=6, deadline=None)
@given(h=st.integers(30, 100), w=st.integers(30, 120))
def test_window_inv_sigma_kernel_matches_ref(h, w):
    rng = np.random.default_rng(h * 77 + w)
    img = jnp.asarray(rng.integers(0, 255, (h, w)).astype(np.float32))
    _, ii_pair = integral_images(img)
    ny, nx = h - 24 + 1, w - 24 + 1
    got = ops.window_inv_sigma_grid(ii_pair, ny, nx, use_kernel=True)
    want = ops.window_inv_sigma_grid(ii_pair, ny, nx, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("stage", [0, 1])
@pytest.mark.parametrize("hw", [(40, 56), (64, 96)])
def test_haar_stage_kernel_matches_ref(stage, hw):
    if stage >= CASC.n_stages:
        pytest.skip("pretrained cascade has fewer stages")
    h, w = hw
    rng = np.random.default_rng(42)
    img = jnp.asarray(rng.integers(0, 255, (h, w)).astype(np.float32))
    ii, ii_pair = integral_images(img)
    ny, nx = h - 24 + 1, w - 24 + 1
    inv = ops.window_inv_sigma_grid(ii_pair, ny, nx, use_kernel=False)
    got = ops.dense_stage_sums(CASC, CASC, stage, ii, inv, interpret=True)
    want = ops.dense_stage_sums_ref(CASC, CASC, stage, ii, inv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_integral_image_property_last_cell_is_total():
    rng = np.random.default_rng(0)
    img = rng.integers(0, 255, (48, 64)).astype(np.float32)
    ii = np.asarray(ops.integral_image(jnp.asarray(img), use_kernel=True,
                                       interpret=True))
    assert abs(ii[-1, -1] - img.sum()) < 1e-2 * img.size
    assert (ii[0] == 0).all() and (ii[:, 0] == 0).all()
