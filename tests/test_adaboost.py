"""AdaBoost cascade training (paper §3, Fig. 3): a quickly-trained tiny
cascade must separate synthetic faces from negatives, and cascade
composition must obey the DR/FPR product rule (Eq. 4)."""

import numpy as np
import pytest

from repro.core.training import train_cascade, TrainConfig
from repro.core.training.data import window_dataset
from repro.core import load_cascade
from repro.configs.viola_jones import DEFAULT_PRETRAINED


@pytest.fixture(scope="module")
def tiny_cascade():
    cfg = TrainConfig(n_stages=2, n_pos=120, n_neg=120, max_features=300,
                      max_weak_per_stage=8, stage_fpr=0.5, stage_dr=0.98,
                      seed=5, verbose=False)
    return train_cascade(cfg)


def test_training_meets_stage_targets(tiny_cascade):
    casc, info = tiny_cascade
    assert casc.n_stages >= 1
    assert info["overall_dr"] >= 0.9
    assert info["overall_fpr"] <= 0.5 ** casc.n_stages + 0.1


def test_eq4_product_rule(tiny_cascade):
    """Overall DR/FPR ≈ per-stage products (paper Eq. 4)."""
    casc, info = tiny_cascade
    drs = [s["dr"] for s in info["stages"]]
    fprs = [s["fpr"] for s in info["stages"]]
    assert info["overall_dr"] <= np.prod(drs) + 0.05
    assert info["overall_fpr"] <= np.prod(fprs) + 0.05


def test_pretrained_separates_fresh_windows():
    """The shipped cascade generalizes to unseen synthetic windows."""
    from repro.core.features import stage_sum_windows
    from repro.core.integral import integral_images, window_inv_sigma
    import jax.numpy as jnp

    casc, _ = load_cascade(DEFAULT_PRETRAINED)
    rng = np.random.default_rng(123)
    X, y = window_dataset(rng, n_pos=40, n_neg=40)

    def passes(img) -> bool:
        ii, ii_pair = integral_images(jnp.asarray(img, jnp.float32))
        inv = window_inv_sigma(ii_pair, jnp.asarray([[0]]),
                               jnp.asarray([[0]]), 24).reshape(-1)
        ys = jnp.zeros((1,), jnp.int32)
        off = np.asarray(casc.stage_offsets)
        for s in range(casc.n_stages):
            ss = stage_sum_windows(casc, ii, ys, ys, inv,
                                   int(off[s]), int(off[s + 1]))
            if float(ss[0]) < float(casc.stage_threshold[s]):
                return False
        return True

    acc_pos = np.mean([passes(X[i]) for i in np.nonzero(y == 1)[0][:25]])
    acc_neg = np.mean([not passes(X[i]) for i in np.nonzero(y == 0)[0][:25]])
    assert acc_pos > 0.7, f"detection rate too low: {acc_pos}"
    assert acc_neg > 0.7, f"false positive rate too high: {1 - acc_neg}"
