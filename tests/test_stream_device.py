"""Device-resident stream state: the on-device tile-change kernels race
their oracle twins and the host planners; `StreamConfig.device_state`
streams are bit-identical to the host-planned path (and to per-frame
``detect``) at threshold 0 across every synthetic scenario, through the
pipelined submit/retire API, the rung-retry loop, and the decode-overflow
fallback; the donated state reuses its buffers with zero steady-state
program builds; and serving sessions report identical stream stats
either way."""

import numpy as np
import pytest

import jax

from repro.core import Detector, EngineConfig, paper_shaped_cascade
from repro.kernels.ops import (tile_change_mask, changed_window_map)
from repro.kernels.ref import (tile_change_mask_ref, changed_window_map_ref)
from repro.serve import DetectorService, PodSpec, ServiceConfig
from repro.stream import (SCENARIOS, StreamConfig, StreamEngine,
                          VideoDetector, make_video, tile_change_scores,
                          dilate_tiles)

CASC = paper_shaped_cascade(0, stage_sizes=[3, 4, 5, 6, 8])
KW = dict(step=2, scale_factor=1.3, min_neighbors=2)
HW = 96
HOST_CFG = StreamConfig(tile=12, threshold=0.0, keyframe_interval=4)
DEV_CFG = HOST_CFG._replace(device_state=True)


@pytest.fixture(scope="module")
def detector():
    return Detector(CASC, EngineConfig(mode="wave", **KW))


def frames_of(kind, n=10, seed=3, h=HW, w=HW):
    return [f for f, _gt in make_video(kind, n_frames=n, h=h, w=w,
                                       seed=seed)]


# ------------------------------------------------- kernels vs oracles/host
@pytest.mark.parametrize("exact", [True, False])
@pytest.mark.parametrize("halo", [0, 1])
def test_tile_change_mask_matches_ref_and_host(exact, halo):
    rng = np.random.default_rng(0)
    prev = rng.random((50, 70), np.float32)
    cur = prev.copy()
    cur[12:19, 33:41] += 0.5          # a localized change
    cur[40, 2] += 1e-3                # a single-pixel tickle
    thr = 0.0 if exact else 1e-4
    changed, scores = tile_change_mask(prev, cur, thr, tile=12, halo=halo,
                                       exact=exact)
    changed_r, scores_r = tile_change_mask_ref(prev, cur, thr, tile=12,
                                               halo=halo, exact=exact)
    assert np.array_equal(np.asarray(changed), np.asarray(changed_r))
    np.testing.assert_allclose(np.asarray(scores), np.asarray(scores_r),
                               rtol=1e-5, atol=1e-7)
    # exact mode matches the host planner's bit-for-bit change test
    if exact:
        _s, host_any = tile_change_scores(prev, cur, 12, exact=True)
        host = dilate_tiles(host_any, halo)
        assert np.array_equal(np.asarray(changed), host)


def test_changed_window_map_matches_ref():
    # windows form a (ny, nx) grid with separable inclusive tile ranges:
    # rows share ty0/ty1, columns share tx0/tx1 (the streaming layout)
    rng = np.random.default_rng(1)
    ty, tx, ny, nx = 7, 9, 6, 8
    changed = rng.random((ty, tx)) < 0.3
    ty0 = rng.integers(0, ty, ny).astype(np.int32)
    ty1 = np.minimum(ty0 + rng.integers(0, 3, ny), ty - 1).astype(np.int32)
    tx0 = rng.integers(0, tx, nx).astype(np.int32)
    tx1 = np.minimum(tx0 + rng.integers(0, 3, nx), tx - 1).astype(np.int32)
    valid = rng.random(ny * nx) < 0.9
    got = changed_window_map(changed, ty0, ty1, tx0, tx1, valid)
    want = changed_window_map_ref(changed, ty0, ty1, tx0, tx1, valid)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # brute-force oracle on top: any changed tile in the inclusive range
    brute = np.array([valid[i * nx + j] and changed[ty0[i]:ty1[i] + 1,
                                                    tx0[j]:tx1[j] + 1].any()
                      for i in range(ny) for j in range(nx)])
    assert np.array_equal(np.asarray(got), brute)


# ------------------------------------------------------- bit-identity
@pytest.mark.parametrize("kind", SCENARIOS)
def test_device_stream_bit_identical_to_host_and_detect(detector, kind):
    vh = VideoDetector(detector, HOST_CFG)
    vd = VideoDetector(detector, DEV_CFG)
    for f in frames_of(kind):
        rh, sh = vh.process(f)
        rd, sd = vd.process(f)
        assert np.array_equal(rh, rd)
        assert sh == sd                  # mode, counters, level accounting
        assert np.array_equal(rd, detector.detect(f))
    assert vd.xfer_bytes > 0             # the accounting actually ran


@pytest.mark.parametrize("kind", SCENARIOS)
def test_pipelined_submit_retire_matches_sequential(detector, kind):
    # all-full streaks exercise the provisional ahead-dispatch (bitmap
    # stale, verdict sound); mixed scenarios exercise its true-up when a
    # successor's verdict commits after a full refresh
    frames = frames_of(kind, n=12, seed=5)
    seq = VideoDetector(detector, DEV_CFG)
    pipe = VideoDetector(detector, DEV_CFG)
    want = [seq.process(f) for f in frames]
    got, prev = [], None
    for f in frames:                     # depth-2 double-buffered loop
        tok = pipe.submit(f)
        if prev is not None:
            got.append(pipe.retire(prev))
        prev = tok
    got.append(pipe.retire(prev))
    for (rw, sw), (rg, sg) in zip(want, got):
        assert np.array_equal(rw, rg) and sw == sg


def test_retry_grows_rung_and_stays_identical(detector):
    # static opening (tiny sticky rung) then a pan burst: the first burst
    # frame overflows the compiled rung, retries at a larger one, and
    # still commits the exact host result
    cfg_h = HOST_CFG._replace(keyframe_interval=0, full_refresh_frac=0.95,
                              max_changed_frac=0.95)
    cfg_d = cfg_h._replace(device_state=True)
    frames = (frames_of("static_cctv", n=3, seed=7)
              + frames_of("camera_pan", n=3, seed=7))
    vh, vd = VideoDetector(detector, cfg_h), VideoDetector(detector, cfg_d)
    rung0 = None
    for f in frames:
        rh, sh = vh.process(f)
        rd, sd = vd.process(f)
        if rung0 is None:
            rung0 = vd._dev_rung
        assert np.array_equal(rh, rd) and sh == sd
    assert vd._dev_rung > rung0          # the sticky rung actually grew


def test_decode_overflow_falls_back_to_full(detector):
    # decode_cap smaller than the survivor count: rects stay identical,
    # the frame is just accounted as a full refresh
    vh = VideoDetector(detector, HOST_CFG)
    vd = VideoDetector(detector, DEV_CFG, decode_cap=4)
    modes = []
    for f in frames_of("moving_face", n=8, seed=9):
        rh, _sh = vh.process(f)
        rd, sd = vd.process(f)
        modes.append(sd.mode)
        assert np.array_equal(rh, rd)
    assert set(modes) == {"full"}


# ------------------------------------------------------------- residency
def test_donated_state_reuses_buffers_and_programs(detector):
    # a stream that settles into steady incremental frames: the donated
    # state must recycle its buffers in place with no new program builds
    eng = StreamEngine(detector, DEV_CFG.max_changed_frac)
    vd = VideoDetector(detector, DEV_CFG._replace(keyframe_interval=0),
                       engine=eng)
    frames = frames_of("static_cctv", n=12, seed=11)
    ptrs, builds, modes = [], [], []
    for f in frames:
        _r, s = vd.process(f)
        modes.append(s.mode)
        if vd._dev_state is not None:
            ptrs.append(vd._dev_state.ref.unsafe_buffer_pointer())
        builds.append(eng.program_builds)
    assert modes[0] == "full" and set(modes[1:]) == {"incremental"}
    # programs compiled by frame 2 (opening rung + one retry growth at
    # most), then reused for every steady-state frame
    assert builds[-1] == builds[2]
    # donation: the reference-frame buffer is recycled in place
    assert len(set(ptrs[2:])) == 1
    # steady state fetches scalars + slots, never the ref/bitmap arrays
    assert vd._ref is None and vd._bitmap is None


def test_device_stream_api_guards(detector):
    vd = VideoDetector(detector, DEV_CFG)
    frame = frames_of("static_cctv", n=1)[0]
    vd.process(frame)
    with pytest.raises(RuntimeError, match="device-resident"):
        vd.plan_frame(frame)
    with pytest.raises(ValueError, match="device_state"):
        vd.reconfigure(DEV_CFG._replace(device_state=False))
    rects, _ = vd.process(frame)
    with pytest.raises(ValueError):      # cached returns are read-only
        rects[...] = 0
    vh = VideoDetector(detector, HOST_CFG)
    with pytest.raises(RuntimeError, match="device_state"):
        vh.submit(frame)
    vd.reset()                           # next frame re-opens cleanly
    r2, s2 = vd.process(frame)
    assert s2.mode == "full"
    assert np.array_equal(r2, detector.detect(frame))


# --------------------------------------------------------------- serving
def test_service_sessions_identical_stats_either_way(detector):
    videos = [frames_of(k, n=6, seed=s)
              for s, k in enumerate(("static_cctv", "moving_face",
                                     "camera_pan"))]
    outs, stream_stats = [], []
    for dev in (False, True):
        svc = DetectorService(detector, ServiceConfig(
            pods=(PodSpec("big", 1.0), PodSpec("little", 0.4)),
            stream_config=HOST_CFG._replace(device_state=dev)))
        sessions = [svc.open_stream() for _ in videos]
        reqs = []
        for t in range(6):
            for sess, vid in zip(sessions, videos):
                reqs.append(sess.submit_frame(vid[t]))
        svc.flush()
        outs.append([(r.result(), r.stats) for r in reqs])
        stream_stats.append(svc.stats().stream.as_dict())
    for (rh, sh), (rd, sd) in zip(*outs):
        assert np.array_equal(rh, rd)
        assert sh == sd
    assert stream_stats[0] == stream_stats[1]


def test_jax_default_backend_is_importable():
    # the device path assumes a working jax backend; make the assumption
    # explicit so failures here are legible
    assert jax.default_backend() in ("cpu", "gpu", "tpu")
