#!/usr/bin/env python
"""CI crossover smoke: the Pallas packed-tail backend must be bit-identical
to the gather oracle everywhere a tail runs.

Covers, on the pretrained cascade and the synthetic test corpus:

1. ``packed_tail.stage_sums`` backend sweep — every cascade stage, at
   deliberately non-rung-aligned survivor counts (odd sizes that exercise
   the kernel's lane-block padding), on a packed list spanning two images
   and two pyramid levels;
2. ``Detector.detect_batch(strategy="packed")`` with the tail forced to
   each backend, on a mixed ``valid_hw`` pad bucket (different true shapes
   inside one compiled program);
3. ``StreamEngine.incremental`` with the tail forced to each backend on a
   moving-face stream (threshold 0), against per-frame ``detect``.

Exit code 0 = all bit-identical.  Run by ``scripts/ci.sh``.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import Detector, EngineConfig  # noqa: E402
from repro.core.cascade import WINDOW  # noqa: E402
from repro.core.integral import integral_images, window_inv_sigma  # noqa: E402
from repro.core.training.data import render_scene  # noqa: E402
from repro.configs.viola_jones import pretrained  # noqa: E402
from repro.kernels import packed_tail  # noqa: E402
from repro.stream import StreamConfig, VideoDetector, make_video  # noqa: E402


def check_stage_sums(casc) -> None:
    """Backend sweep on a two-image, two-level packed list, odd sizes."""
    rng = np.random.default_rng(0)
    levels = [(80, 96), (56, 64)]                 # (h, w) per pyramid level
    sats, pairs, bases, strides = [], [], [], []
    base = 0
    for h, w in levels:
        imgs = np.stack([render_scene(rng, h, w, n_faces=1)[0]
                         for _ in range(2)])
        ii = np.stack([np.asarray(integral_images(jnp.asarray(im))[0])
                       for im in imgs])
        pr = np.stack([np.asarray(integral_images(jnp.asarray(im))[1])
                       for im in imgs])
        sats.append(ii.reshape(2, -1))
        pairs.append((pr, h, w))
        bases.append(base)
        strides.append(w + 1)
        base += (h + 1) * (w + 1)
    ii_flat = jnp.asarray(np.concatenate(sats, axis=1))

    for cap in (37, 317, 1111):                   # non-rung-aligned counts
        lv = rng.integers(0, len(levels), cap)
        img = rng.integers(0, 2, cap).astype(np.int32)
        ys = np.asarray([rng.integers(0, levels[v][0] - WINDOW + 1)
                         for v in lv], np.int32)
        xs = np.asarray([rng.integers(0, levels[v][1] - WINDOW + 1)
                         for v in lv], np.int32)
        b = np.asarray([bases[v] for v in lv], np.int32)
        st = np.asarray([strides[v] for v in lv], np.int32)
        inv = np.empty(cap, np.float32)
        for i in range(cap):
            pr, _h, _w = pairs[lv[i]]
            inv[i] = np.asarray(window_inv_sigma(
                jnp.asarray(pr[img[i]]), jnp.asarray(ys[i]),
                jnp.asarray(xs[i]), WINDOW))
        args = (ii_flat, jnp.asarray(img), jnp.asarray(b), jnp.asarray(st),
                jnp.asarray(ys), jnp.asarray(xs), jnp.asarray(inv))
        want = np.asarray(packed_tail.stage_sums(
            casc, casc, 0, casc.n_stages, *args, backend="gather"))
        for bk in ("bulk", "pallas"):
            got = np.asarray(packed_tail.stage_sums(
                casc, casc, 0, casc.n_stages, *args, backend=bk))
            assert np.array_equal(got, want), (
                f"stage_sums backend={bk} diverged at cap={cap}: "
                f"max|d|={np.abs(got - want).max()}")
        print(f"  stage_sums cap={cap}: all stages bit-identical "
              f"(gather == bulk == pallas)")


def check_detect_batch(casc) -> None:
    """Forced-backend detect_batch on a mixed-shape pad bucket."""
    rng = np.random.default_rng(1)
    shapes = [(96, 96), (80, 90), (88, 70)]       # one (96, 96) bucket
    imgs = [render_scene(rng, h, w, n_faces=1)[0] for h, w in shapes]
    kw = dict(mode="wave", step=1, scale_factor=1.2, min_neighbors=2,
              dense_segments=(1,), pad_multiple=96)
    want = Detector(casc, EngineConfig(tail_backend="gather", **kw)
                    ).detect_batch(imgs, strategy="packed")
    for bk in ("bulk", "pallas"):
        got = Detector(casc, EngineConfig(tail_backend=bk, **kw)
                       ).detect_batch(imgs, strategy="packed")
        for i, (g, w_) in enumerate(zip(got, want)):
            assert np.array_equal(g, w_), (
                f"detect_batch backend={bk} diverged on image {i}")
    print(f"  detect_batch: mixed valid_hw bucket bit-identical across "
          f"backends ({len(imgs)} images)")


def check_stream(casc) -> None:
    """Forced-backend incremental streaming vs per-frame detect."""
    video = make_video("static_cctv", n_frames=4, h=96, w=96, seed=5)
    kw = dict(mode="wave", step=2, scale_factor=1.3, min_neighbors=2)
    ref_det = Detector(casc, EngineConfig(tail_backend="gather", **kw))
    for bk in ("gather", "bulk", "pallas"):
        det = Detector(casc, EngineConfig(tail_backend=bk, **kw))
        vd = VideoDetector(det, StreamConfig(tile=12, threshold=0.0,
                                             keyframe_interval=0))
        n_incr = 0
        for frame, _gt in video:
            rects, st = vd.process(frame)
            assert np.array_equal(rects, ref_det.detect(frame)), (
                f"stream backend={bk} diverged on frame {st.frame_idx}")
            n_incr += st.mode == "incremental"
        assert n_incr > 0, "fixture never exercised the incremental tail"
    print("  stream incremental: bit-identical across backends "
          "(threshold 0, mostly-static scene)")


def main() -> None:
    casc, _ = pretrained()
    print("crossover smoke: pallas packed tail vs gather oracle")
    check_stage_sums(casc)
    check_detect_batch(casc)
    check_stream(casc)
    print("crossover smoke OK")


if __name__ == "__main__":
    main()
