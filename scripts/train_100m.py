"""End-to-end driver (deliverable b): train a ~115M-parameter dense LM
for a few hundred steps on this CPU with the full production stack
(pipeline → train_step → AdamW → atomic checkpoints → restart driver).

    PYTHONPATH=src python scripts/train_100m.py --steps 300
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig            # noqa: E402
from repro.launch.train import train_loop             # noqa: E402
from repro.distributed.fault import run_with_restarts  # noqa: E402
from repro.models import param_count                  # noqa: E402

CFG_100M = ModelConfig(
    name="repro-115m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=50304,
    param_dtype="float32",
    compute_dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    args = ap.parse_args()

    print(f"config: {CFG_100M.name}, N = {param_count(CFG_100M) / 1e6:.1f}M "
          f"params", flush=True)

    def loop(attempt):
        return train_loop(cfg=CFG_100M, steps=args.steps, batch=args.batch,
                          seq=args.seq, ckpt=args.ckpt, lr=6e-4,
                          ckpt_every=50, log_every=10)

    out = run_with_restarts(loop, max_restarts=2)
    print("final:", {k: round(v, 4) for k, v in out.items()
                     if k in ("loss", "nll", "accuracy")})


if __name__ == "__main__":
    main()
