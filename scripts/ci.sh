#!/usr/bin/env bash
# Tier-1 gate + a reference-mode benchmark smoke, as run by CI.
#   ./scripts/ci.sh          full tier-1 + bench smoke
#   FAST=1 ./scripts/ci.sh   same suite, fast benchmark settings only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest ==="
python -m pytest -x -q

echo "=== lint: dead stores (assignments overwritten before use) ==="
python scripts/check_dead_stores.py src tests benchmarks scripts examples

echo "=== smoke: packed-tail crossover (pallas == gather oracle, bit-exact) ==="
python scripts/crossover_smoke.py

echo "=== smoke: plan layer (ladder-chosen backends bit-exact, stats reflect plan) ==="
python scripts/plan_smoke.py

echo "=== smoke: bench_detector (batched head + packed-tail crossover, fast) ==="
python -m benchmarks.run --fast --only bench_detector --artifacts .

echo "=== smoke: bench_rit (content/RIT relation, fast) ==="
python -m benchmarks.run --fast --only bench_rit

echo "=== smoke: bench_video (tile-reuse + level skip + tail rungs, fast) ==="
python -m benchmarks.run --fast --only bench_video --artifacts .

echo "CI OK"
