#!/usr/bin/env bash
# Tier-1 gate + a reference-mode benchmark smoke, as run by CI.
#   ./scripts/ci.sh          full tier-1 + bench smoke
#   FAST=1 ./scripts/ci.sh   same suite, fast benchmark settings only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest ==="
python -m pytest -x -q

echo "=== analysis: repro.analysis (trace-safety, plan-IR contracts, kernel oracles) ==="
python -m repro.analysis src tests benchmarks scripts examples --json ANALYSIS.json
python - <<'EOF'
# the gate must stay fast enough to run on every push (budget: < 5s)
import json
secs = json.load(open("ANALYSIS.json"))["seconds"]
assert secs < 5.0, f"repro.analysis took {secs}s (budget 5s) — profile it"
print(f"repro.analysis budget OK: {secs}s < 5s")
EOF

echo "=== smoke: packed-tail crossover (pallas == gather oracle, bit-exact) ==="
python scripts/crossover_smoke.py

echo "=== smoke: plan layer (ladder-chosen backends bit-exact, stats reflect plan) ==="
python scripts/plan_smoke.py

echo "=== smoke: bench_kernels (fused head vs split, bit-exact + crossover) ==="
python -m benchmarks.run --fast --only bench_kernels --artifacts .
python - <<'EOF'
# The fused Haar-head megakernel must be bit-exact against the split
# three-dispatch path at every pyramid level of the dense workload, and
# wherever the autotuner's crossover chose "fused" the fused timing must
# actually be at least as fast as split (1.25x timing-noise tolerance).
import json

rows = json.load(open("BENCH_kernels.json"))["rows"]
fused = [r for r in rows if r.get("kernel") == "fused_head"]
assert fused, "no fused_head rows in BENCH_kernels.json"
assert all(r["bit_exact"] for r in fused), \
    "fused head not bit-exact vs the split path"
chosen = [r for r in fused if r["mode"] == "fused"]
for r in chosen:
    assert r["fused_ms"] <= r["split_ms"] * 1.25, \
        f"tuner chose fused at {r['shape']} but fused is slower " \
        f"({r['fused_ms']:.2f}ms vs {r['split_ms']:.2f}ms)"
tuned = next(r for r in rows if r.get("kernel") == "fused_head_autotune")
print(f"fused head OK: bit-exact at {len(fused)} level(s), fused wins "
      f"{len(chosen)}/{len(fused)}, tile={tuned['shape']}, "
      f"crossover={tuned['crossover']}")
EOF

echo "=== smoke: bench_detector (batched head/tail split + crossover, fast) ==="
python -m benchmarks.run --fast --only bench_detector --artifacts .

echo "=== smoke: bench_rit (content/RIT relation, fast) ==="
python -m benchmarks.run --fast --only bench_rit

echo "=== smoke: bench_video (tile-reuse + level skip + tail rungs, fast) ==="
python -m benchmarks.run --fast --only bench_video --artifacts .
python - <<'EOF'
# High-motion streams must track per-frame detect within 5%: for each
# adversarial scenario the better of the host-planned and device-resident
# rows must reach 0.95x (3% timing-noise tolerance on the ratio, in line
# with the other benchmark gates).  Device-resident streaming must keep
# threshold-0 bit-identity with zero warmed rebuilds, and the static
# stream's FPS must strictly improve over the host-planned path.
import json

rows = json.load(open("BENCH_video.json"))["rows"]
by = {r["scenario"]: r for r in rows}
for kind in ("moving_face", "camera_pan"):
    host, dev = by[kind], by[kind + " (device)"]
    best = max(host["speedup"], dev["speedup"])
    assert best >= 0.95 * 0.97, \
        f"{kind}: streaming fell to {best:.3f}x of per-frame detect " \
        f"(host {host['speedup']:.3f}, device {dev['speedup']:.3f})"
    for r in (host, dev):
        assert r["exact"] is True, f"{r['scenario']} lost bit-identity"
devrows = [r for r in rows if r.get("device")]
assert devrows, "no device-resident rows in BENCH_video.json"
for r in devrows:
    if r["threshold"] <= 0:
        assert r["exact"] is True, f"{r['scenario']} lost bit-identity"
    assert r["rebuilds"] == 0, f"{r['scenario']} rebuilt programs warm"
st_h, st_d = by["static_cctv"], by["static_cctv (device)"]
assert st_d["stream_fps"] > st_h["stream_fps"], \
    f"device-resident static stream no faster than host " \
    f"({st_d['stream_fps']:.1f} vs {st_h['stream_fps']:.1f} fps)"
assert st_d["host_xfer"] < st_h["host_xfer"] * 2, \
    "device static stream moves unexpectedly much host<->device traffic"
print(f"video stream OK: static {st_h['stream_fps']:.0f}->"
      f"{st_d['stream_fps']:.0f} fps device-resident, high-motion "
      + ", ".join(f"{k} {max(by[k]['speedup'], by[k + ' (device)']['speedup']):.2f}x"
                  for k in ("moving_face", "camera_pan")))
EOF

echo "=== smoke: bench_energy (DES energy + serving governor Pareto, fast) ==="
python -m benchmarks.run --fast --only bench_energy --artifacts .
python - <<'EOF'
# The governor must meet the SLO at least as often as either static
# extreme at every point of BENCH_energy.json's Pareto front, and at some
# SLO beat both extremes on modeled Joules/detection (5% model-drift tol).
import json

rows = json.load(open("BENCH_energy.json"))["rows"]
serving = [r for r in rows if r.get("mode") == "serving"]
by_slo = {}
for r in serving:
    by_slo.setdefault(round(r["slo_ms"], 3), {})[r["policy"]] = r
assert by_slo, "no serving rows in BENCH_energy.json"
wins = 0
for slo, pol in sorted(by_slo.items()):
    gov, mx, lt = pol["energy"], pol["max"], pol["little"]
    assert gov["slo_met_frac"] >= max(mx["slo_met_frac"],
                                      lt["slo_met_frac"]) - 1e-9, \
        f"governor misses SLO more than an extreme at slo={slo}ms"
    for ext in (mx, lt):
        if ext["slo_met_frac"] >= gov["slo_met_frac"] - 1e-9:
            assert gov["J_per_detection"] <= \
                ext["J_per_detection"] * 1.05, \
                f"governor beaten by {ext['policy']} at slo={slo}ms"
    # Pareto-dominance at this SLO: against each extreme the governor
    # either buys strictly better SLO attainment, or matches/beats its
    # energy (2% model-drift tolerance)
    if all(gov["slo_met_frac"] > ext["slo_met_frac"] + 1e-9
           or gov["J_per_detection"] <= 1.02 * ext["J_per_detection"]
           for ext in (mx, lt)):
        wins += 1
assert wins >= 1, "governor never Pareto-dominates both static extremes"
print(f"governor Pareto OK: dominates-or-ties both extremes at "
      f"{wins}/{len(by_slo)} SLO points")
EOF

echo "=== smoke: bench_fleet (multi-tenant tiers + admission + ladder, fast) ==="
python -m benchmarks.run --fast --only bench_fleet --artifacts .
python - <<'EOF'
# Tier contract from BENCH_fleet.json: under overload (2x) the realtime
# tier's p95 must stay at or under best-effort's, realtime must meet its
# SLO, no frame may be dropped before the degradation ladder is exhausted,
# and tiered serving must not cost aggregate throughput vs the no-tier
# single-flush baseline (2% model tolerance).
import json

rows = json.load(open("BENCH_fleet.json"))["rows"]
over = max(r["load"] for r in rows if r.get("mode") == "sim_summary")
assert over >= 2.0, f"no overload point in BENCH_fleet.json (max {over}x)"
tiers = {r["tier"]: r for r in rows
         if r.get("mode") == "sim" and r["load"] == over}
assert tiers["realtime"]["latency_ms_p95"] <= \
    tiers["best_effort"]["latency_ms_p95"] + 1e-9, \
    "realtime p95 exceeds best_effort p95 under overload"
assert tiers["realtime"]["slo_met"], "realtime misses its SLO under overload"
summ = next(r for r in rows
            if r.get("mode") == "sim_summary" and r["load"] == over)
if max(summ["ladder_levels"]) < 3:    # ladder not exhausted -> zero drops
    assert summ["frames_dropped"] == 0, \
        "frames dropped before the degradation ladder was exhausted"
assert summ["windows_per_s"] >= 0.98 * summ["baseline_windows_per_s"], \
    "tiered fleet throughput fell below the no-tier baseline"
print(f"fleet tier contract OK at {over}x: rt p95 "
      f"{tiers['realtime']['latency_ms_p95']:.1f}ms <= be p95 "
      f"{tiers['best_effort']['latency_ms_p95']:.1f}ms, "
      f"dropped={summ['frames_dropped']:.0f}, "
      f"degrade_events={summ['degrade_events']}")
EOF

echo "CI OK"
