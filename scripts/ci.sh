#!/usr/bin/env bash
# Tier-1 gate + a reference-mode benchmark smoke, as run by CI.
#   ./scripts/ci.sh          full tier-1 + bench smoke
#   FAST=1 ./scripts/ci.sh   same suite, fast benchmark settings only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest ==="
python -m pytest -x -q

echo "=== lint: dead stores (assignments overwritten before use) ==="
python scripts/check_dead_stores.py src tests benchmarks scripts examples

echo "=== smoke: bench_detector (ref/dense vs ours + pallas batched head, fast) ==="
python -m benchmarks.run --fast --only bench_detector

echo "=== smoke: bench_rit (content/RIT relation, fast) ==="
python -m benchmarks.run --fast --only bench_rit

echo "=== smoke: bench_video (streaming tile-reuse + level-subset skip, fast) ==="
python -m benchmarks.run --fast --only bench_video

echo "CI OK"
