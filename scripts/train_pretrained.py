"""Train the shipped pretrained cascade (stronger config, background run)."""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core.training import train_cascade, TrainConfig  # noqa: E402
from repro.core import save_cascade                         # noqa: E402

cfg = TrainConfig(n_stages=14, n_pos=1200, n_neg=1200, max_features=3500,
                  max_weak_per_stage=60, stage_fpr=0.4, stage_dr=0.997,
                  seed=7, verbose=True)
casc, info = train_cascade(cfg)
save_cascade("/root/repo/src/repro/configs/pretrained/synthetic_face_v2.npz",
             casc, {"config": cfg._asdict(), "stages": info["stages"],
                    "overall_dr": info["overall_dr"],
                    "overall_fpr": info["overall_fpr"]})
print("DONE", casc.n_weak, "wc", casc.n_stages, "stages",
      "DR", info["overall_dr"], "FPR", info["overall_fpr"])
