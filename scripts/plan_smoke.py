#!/usr/bin/env python
"""CI plan-layer smoke: backends selected *through the plan* stay bit-exact,
and the serving stats reflect the plan's choices.

Complements ``crossover_smoke.py`` (which forces backends via
``tail_backend``): here the backend decisions flow the production way —
``EngineConfig.tail_rungs`` ladder -> ``compile_plan`` -> per-segment /
per-rung ``SegmentPlan.backend`` -> executor.  Covers, on the pretrained
cascade:

1. hand-built ladders that force each backend at the active rung: the
   packed batched engine and the threshold-0 incremental stream must be
   bit-identical across all three, and the compiled plans must report the
   ladder's backend per tail segment;
2. a mixed ladder: the plan picks *different* backends at different
   capacities, exactly as ``repro.plan.select_backend`` dictates;
3. ``DetectorService.warmup(tune_tail=True)``: ``stats().tail`` must
   carry the measured rungs and the plan-chosen per-segment backends of
   the warmed bucket, consistent with the compiled plan.

Exit code 0 = all checks pass.  Run by ``scripts/ci.sh``.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

import repro.plan as planlib  # noqa: E402
from repro.core import Detector, EngineConfig  # noqa: E402
from repro.core.training.data import render_scene  # noqa: E402
from repro.configs.viola_jones import pretrained  # noqa: E402
from repro.serve import DetectorService, ServiceConfig  # noqa: E402
from repro.stream import StreamConfig, VideoDetector, make_video  # noqa: E402

KW = dict(mode="wave", step=2, scale_factor=1.3, min_neighbors=2,
          dense_segments=(1,), tail_backend="auto")


def check_forced_ladders(casc) -> None:
    """Each backend forced through the ladder: identical outputs, and the
    compiled plan reports that backend on every tail segment / rung."""
    rng = np.random.default_rng(0)
    imgs = [render_scene(rng, 96, 96, n_faces=1)[0] for _ in range(3)]
    video = make_video("moving_face", n_frames=4, h=96, w=96, seed=5)
    want_batch = want_stream = None
    for bk in ("gather", "bulk", "pallas"):
        cfg = EngineConfig(tail_rungs=((10 ** 9, bk),), **KW)
        det = Detector(casc, cfg)
        bplan = det.batch_plan(96, 96, len(imgs))
        assert bplan.tail_segments, "fixture must exercise a packed tail"
        assert all(s.backend == bk for s in bplan.tail_segments), bplan
        splan = planlib.compile_plan(cfg, det.n_stages, 96, 96,
                                     levels=(0, 1), capacity=512)
        assert splan.segments[0].backend == bk
        got_b = det.detect_batch(imgs, strategy="packed")
        vd = VideoDetector(det, StreamConfig(tile=16, threshold=0.0,
                                             keyframe_interval=0))
        got_s = [vd.process(f)[0] for f, _gt in video]
        if want_batch is None:
            want_batch, want_stream = got_b, got_s
        else:
            assert all(np.array_equal(a, b)
                       for a, b in zip(want_batch, got_b)), bk
            assert all(np.array_equal(a, b)
                       for a, b in zip(want_stream, got_s)), bk
    print("  forced ladders: gather == bulk == pallas through the plan "
          "(batch + threshold-0 stream)")


def check_mixed_ladder(casc) -> None:
    ladder = ((256, "gather"), (2048, "bulk"), (1 << 30, "pallas"))
    cfg = EngineConfig(tail_rungs=ladder, **KW)
    det = Detector(casc, cfg)
    for cap, want in ((100, "gather"), (256, "gather"), (300, "bulk"),
                      (5000, "pallas")):
        plan = planlib.compile_plan(cfg, det.n_stages, 96, 96,
                                    levels=(0,), capacity=cap)
        got = plan.segments[0].backend
        assert got == want == planlib.select_backend(cfg, cap), (cap, got)
    bplan = det.batch_plan(96, 96, 2)
    for seg in bplan.tail_segments:
        assert seg.backend == planlib.select_backend(cfg, seg.capacity)
    print(f"  mixed ladder: plan picks per-capacity backends "
          f"{[(s.capacity, s.backend) for s in bplan.tail_segments]}")


def check_service_stats(casc) -> None:
    rng = np.random.default_rng(1)
    probe = render_scene(rng, 96, 96, n_faces=1)[0]
    det = Detector(casc, EngineConfig(**KW))
    svc = DetectorService(det, ServiceConfig(batch_sizes=(1, 2, 4),
                                             max_batch=4))
    svc.warmup(probe, tune_tail=True)
    st = svc.stats().tail
    cfg = svc.detector.config
    assert cfg.tail_backend == "auto" and cfg.tail_rungs
    assert st.rungs == tuple(tuple(r) for r in cfg.tail_rungs)
    assert st.chosen, "warmup must record plan-chosen backends"
    bplan = svc.detector.batch_plan(96, 96, 4)
    assert st.chosen == tuple((s.capacity, s.backend)
                              for s in bplan.tail_segments)
    for cap, bk in st.chosen:
        assert bk == planlib.select_backend(cfg, cap)
    print(f"  service stats: rungs={st.rungs} chosen={st.chosen}")


def main() -> None:
    casc, _ = pretrained()
    print("plan smoke: backend selection through the plan layer")
    check_forced_ladders(casc)
    check_mixed_ladder(casc)
    check_service_stats(casc)
    print("plan smoke OK")


if __name__ == "__main__":
    main()
