"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from the dry-run
artifacts (artifacts/dryrun_*.json).  Prints markdown to stdout."""

import json

ART = {"16x16": "artifacts/dryrun_16x16.json",
       "pod2x16x16": "artifacts/dryrun_pod2.json"}


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def main():
    rows = []
    for mesh, path in ART.items():
        try:
            rows += json.load(open(path))
        except FileNotFoundError:
            print(f"<!-- missing {path} -->")
    print("### Dry-run results (lower + compile per cell)\n")
    print("| arch | shape | mesh | compile | GiB/device | coll GiB/dev |"
          " status |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - |"
                  f" FAILED: {r.get('error', '?')[:60]} |")
            continue
        mem = r["memory"].get("bytes_per_device", 0) / 2 ** 30
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['t_compile_s']:.0f}s | {mem:.2f} "
              f"| {r['collective_bytes'] / 2 ** 30:.1f} | ok |")

    print("\n### Roofline table (single-pod 16×16; terms per step)\n")
    print("| arch | shape | compute | memory | collective | dominant "
          "| roofline frac | useful ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if not r.get("ok") or r["mesh"] != "16x16":
            continue
        f = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {fmt_s(f['compute_s'])} "
              f"| {fmt_s(f['memory_s'])} | {fmt_s(f['collective_s'])} "
              f"| {f['dominant'].replace('_s', '')} "
              f"| {f['roofline_fraction']:.3f} "
              f"| {f['useful_flops_ratio']:.2f} |")


if __name__ == "__main__":
    main()
